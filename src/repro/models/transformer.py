"""Decoder-only LM assembly: embedding (decoupled gather), scan-over-layer
segments, LM head, loss, and the KV-cache decode step."""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dae_gather.ops import dae_gather
from repro.models.blocks import (block_apply, block_cache_init,
                                 block_cache_init_paged, block_init)
from repro.models.common import (ModelConfig, cross_entropy_loss, dense_init,
                                 rmsnorm, rmsnorm_init)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_init(cfg: ModelConfig, kind: str, count: int, key) -> Params:
    keys = jax.random.split(key, count)
    return jax.vmap(lambda k: block_init(cfg, kind, k))(keys)


def lm_init(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, len(cfg.layer_specs()) + 3)
    params: Params = {
        "embed": dense_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
        "segments": [
            _stack_init(cfg, spec.kind, spec.count, ks[i + 1])
            for i, spec in enumerate(cfg.layer_specs())
        ],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[-1], cfg.d_model, cfg.vocab,
                                       cfg.pdtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params: Params, tokens: jnp.ndarray
                 ) -> jnp.ndarray:
    """Vocab-table gather — the framework's dae_gather hook."""
    b, s = tokens.shape
    if cfg.kernel_mode == "pallas":
        flat = dae_gather(params["embed"], tokens.reshape(-1).astype(jnp.int32))
        return flat.reshape(b, s, cfg.d_model).astype(cfg.adtype)
    return jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)


def _sp_constraint(cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Sequence-parallel residual stream (Megatron SP): between blocks the
    activations shard their token axis over the TP axis, turning the
    row-parallel all-reduce into reduce-scatter + all-gather (half the
    link bytes, overlappable)."""
    if not cfg.act_sp:
        return x
    from jax.sharding import PartitionSpec as P
    dp = cfg.mesh_dp_axes if len(cfg.mesh_dp_axes) > 1 else \
        cfg.mesh_dp_axes[0]
    return jax.lax.with_sharding_constraint(
        x, P(dp, cfg.mesh_tp_axis, None))


def _segment_scan(cfg: ModelConfig, kind: str, stacked: Params,
                  x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    def body(h, layer_params):
        h = _sp_constraint(cfg, h)
        h2, _ = block_apply(cfg, kind, layer_params, h, positions)
        h2 = _sp_constraint(cfg, h2)
        return h2, None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    if not cfg.scan_layers:  # unrolled: used by the dry-run cost probes
        count = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(count):
            x, _ = body(x, jax.tree.map(lambda a: a[i], stacked))
        return x
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def lm_apply(cfg: ModelConfig, params: Params, tokens: jnp.ndarray,
             positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """tokens (B, S) -> logits (B, S, V)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(cfg, params, tokens)
    for spec, stacked in zip(cfg.layer_specs(), params["segments"]):
        x = _segment_scan(cfg, spec.kind, stacked, x, positions)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_out = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = x @ w_out.astype(cfg.adtype)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits


def lm_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    logits = lm_apply(cfg, params, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def lm_cache_init(cfg: ModelConfig, batch: int, s_max: int) -> List[Any]:
    caches = []
    for spec in cfg.layer_specs():
        one = block_cache_init(cfg, spec.kind, batch, s_max)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (spec.count,) + a.shape), one))
    return caches


def lm_cache_init_paged(cfg: ModelConfig, batch: int, n_pages: int,
                        page: int) -> List[Any]:
    """Paged decode caches: KV pages are pooled across all ``batch``
    slots; each layer of a segment gets its own pool (leaf shape
    ``(count, n_pages, ...)``) addressed by one shared page table."""
    caches = []
    for spec in cfg.layer_specs():
        one = block_cache_init_paged(cfg, spec.kind, batch, n_pages, page)
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (spec.count,) + a.shape), one))
    return caches


_PAGE_KEYS = ("kp", "vp", "ckvp", "krp")


def lm_copy_pages(caches: List[Any], src: jnp.ndarray, dst: jnp.ndarray
                  ) -> List[Any]:
    """Copy physical page ``src`` into page ``dst`` in every layer —
    the allocator's copy-on-write primitive.  src/dst are int32 scalars
    (traced, so one jit covers every page pair)."""
    out = []
    for cache in caches:
        new = dict(cache)
        attn = dict(cache["attn"])
        for key in _PAGE_KEYS:
            if key in attn:
                a = attn[key]
                attn[key] = a.at[:, dst].set(a[:, src])
        new["attn"] = attn
        out.append(new)
    return out


def lm_gather_pages(caches: List[Any], pages: jnp.ndarray) -> List[Any]:
    """Pull physical pages ``pages`` (NPB,) int32 out of every layer's
    pool: leaf (count, NP, ...) -> block (count, NPB, ...).  One half of
    the disaggregated prefill->decode migration — the blocks keep the
    pool layout, so the matching :func:`lm_scatter_pages` on another
    mesh is a pure placement move."""
    out = []
    for cache in caches:
        blk = {}
        attn = cache["attn"]
        for key in _PAGE_KEYS:
            if key in attn:
                blk[key] = jnp.take(attn[key], pages, axis=1)
        out.append(blk)
    return out


def lm_scatter_pages(caches: List[Any], blocks: List[Any],
                     pages: jnp.ndarray, slot: jnp.ndarray,
                     new_len: jnp.ndarray) -> List[Any]:
    """Write migrated ``blocks`` (from :func:`lm_gather_pages`) into
    physical pages ``pages`` of every layer's pool and set slot
    ``slot``'s logical length to ``new_len``.  Page lists padded with
    page 0 (the allocator's reserved trash page) are safe: its contents
    are never attended."""
    out = []
    for cache, blk in zip(caches, blocks):
        new = dict(cache)
        attn = dict(cache["attn"])
        for key in _PAGE_KEYS:
            if key in attn:
                a = attn[key]
                attn[key] = a.at[:, pages].set(blk[key].astype(a.dtype))
        ln = attn["len"]
        onehot = jnp.arange(ln.shape[1]) == slot
        attn["len"] = jnp.where(onehot[None, :], new_len.astype(ln.dtype),
                                ln)
        new["attn"] = attn
        out.append(new)
    return out


def lm_paged_reset(caches: List[Any], keep: jnp.ndarray,
                   new_lens: jnp.ndarray) -> List[Any]:
    """Reset per-slot logical lengths for slots where ``keep`` is False
    (to ``new_lens``, e.g. a reused prefix length).  Page contents are
    untouched: positions < len are always freshly written by prefill
    and positions >= len are masked out of attention."""
    out = []
    for cache in caches:
        new = dict(cache)
        attn = dict(cache["attn"])
        ln = attn["len"]
        attn["len"] = jnp.where(keep[None, :], ln,
                                new_lens[None, :].astype(ln.dtype))
        new["attn"] = attn
        out.append(new)
    return out


def lm_decode_step(cfg: ModelConfig, params: Params, caches: List[Any],
                   token: jnp.ndarray, pos: jnp.ndarray
                   ) -> Tuple[jnp.ndarray, List[Any]]:
    """One decode step: token (B,), pos (B,) -> (logits (B, V), caches)."""
    b = token.shape[0]
    positions = pos[:, None]
    x = embed_tokens(cfg, params, token[:, None])

    new_caches = []
    for spec, stacked, cache in zip(cfg.layer_specs(), params["segments"],
                                    caches):
        def body(h, pc):
            layer_params, layer_cache = pc
            h2, nc = block_apply(cfg, spec.kind, layer_params, h, positions,
                                 cache=layer_cache)
            return h2, nc

        if not cfg.scan_layers:
            ncs = []
            for i in range(spec.count):
                x, nci = body(x, jax.tree.map(lambda a: a[i], (stacked, cache)))
                ncs.append(nci)
            nc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
        else:
            x, nc = jax.lax.scan(body, x, (stacked, cache))
        new_caches.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w_out = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (x[:, 0] @ w_out.astype(cfg.adtype)).astype(jnp.float32)
    return logits, new_caches


def lm_prefill(cfg: ModelConfig, params: Params, caches: List[Any],
               tokens: jnp.ndarray, pos: jnp.ndarray, n_valid: jnp.ndarray,
               page_table: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, List[Any]]:
    """Chunked, batched, teacher-forced cache fill — the serving Access
    engine's step (paper §3: the decoupled access stream).

    tokens (B, C) int32 — the next C prompt tokens per slot; pos (B,) —
    each slot's current sequence position (== its cache length);
    n_valid (B,) — how many of the C tokens are real per slot (0 leaves
    that slot's cache, recurrent state and position untouched).

    Returns (logits (B, V) float32 taken at each slot's LAST VALID
    token, new caches).  A C=1 call with n_valid in {0, 1} is a masked
    decode step — the Execute engine uses exactly that, so prefill and
    decode share this one primitive (compiled once per chunk width).
    """
    b, c = tokens.shape
    positions = pos[:, None] + jnp.arange(c, dtype=pos.dtype)[None, :]
    valid = jnp.arange(c)[None, :] < n_valid[:, None]
    x = embed_tokens(cfg, params, tokens)

    new_caches = []
    for spec, stacked, cache in zip(cfg.layer_specs(), params["segments"],
                                    caches):
        def body(h, pc):
            layer_params, layer_cache = pc
            h2, nc = block_apply(cfg, spec.kind, layer_params, h, positions,
                                 cache=layer_cache, valid=valid,
                                 page_table=page_table)
            return h2, nc

        if not cfg.scan_layers:
            ncs = []
            for i in range(spec.count):
                x, nci = body(x, jax.tree.map(lambda a: a[i], (stacked, cache)))
                ncs.append(nci)
            nc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
        else:
            x, nc = jax.lax.scan(body, x, (stacked, cache))
        new_caches.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.clip(n_valid - 1, 0, c - 1)[:, None, None]
    xl = jnp.take_along_axis(x, last, axis=1)[:, 0]            # (B, D)
    w_out = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = (xl @ w_out.astype(cfg.adtype)).astype(jnp.float32)
    return logits, new_caches


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
