"""Quickstart: the DAE4HLS ideas in 60 seconds.

1. The paper's programming model, simulated cycle-accurately.
2. The TPU-native decoupled ops (Pallas kernels, interpret mode on CPU).
3. A tiny LM train step using the framework.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np


def demo_simulator():
    from repro.core.workloads import run_workload
    print("== 1. Explicit decoupling in the cycle simulator ==")
    base = run_workload("hashtable", "vitis", scale="small")
    dec = run_workload("hashtable", "rhls_dec", scale="small")
    print(f"   hashtable  coupled   : {base.cycles:>8d} cycles")
    print(f"   hashtable  decoupled : {dec.cycles:>8d} cycles "
          f"({base.cycles / dec.cycles:.1f}x, paper band 10-79x)")


def demo_decoupled_ops():
    from repro.core.decouple import (decoupled_gather, decoupled_merge,
                                     decoupled_searchsorted, plan_rif)
    print("== 2. Decoupled TPU ops (Pallas, interpret on CPU) ==")
    r = np.random.default_rng(0)
    table = jnp.asarray(r.standard_normal((512, 128)), jnp.float32)
    idx = jnp.asarray(r.integers(0, 512, 64), jnp.int32)
    rows = decoupled_gather(table, idx, method="rif", chunk=16, rif=4)
    print(f"   decoupled_gather: {rows.shape}, matches take:",
          bool(jnp.allclose(rows, table[idx])))
    a = jnp.sort(jnp.asarray(r.standard_normal(256), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(256), jnp.float32))
    m = decoupled_merge(a, b, tile=128)
    print("   decoupled_merge sorted:", bool((m[1:] >= m[:-1]).all()))
    keys = jnp.asarray(r.standard_normal(16), jnp.float32)
    ss = decoupled_searchsorted(a, keys)
    print("   decoupled_searchsorted:", np.asarray(ss)[:6], "...")
    plan = plan_rif(block_bytes=128 * 4)
    print(f"   RIF plan for 512B blocks: rif={plan.rif} ({plan.note})")


def demo_train_step():
    from repro.configs import get_config
    from repro.launch.steps import make_train_step
    from repro.models.registry import build_model
    from repro.optim import AdamW
    print("== 3. Tiny LM train step ==")
    cfg = get_config("qwen3-4b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    opt_state = opt.init(params)
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    for i in range(5):
        params, opt_state, metrics = step(params, opt_state, batch)
        print(f"   step {i}: loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    demo_simulator()
    demo_decoupled_ops()
    demo_train_step()
