"""Paper Table 1: cycles for all benchmarks x HLS configs at paper scale,
side-by-side with the published numbers."""

from __future__ import annotations

from repro.core.simulator import DeadlockError
from repro.core.workloads import BENCHMARKS, CONFIGS, run_workload

PAPER_TABLE1 = {
    ("binsearch", "vitis"): 2_298_439, ("binsearch", "vitis_dec"): 65_091,
    ("binsearch", "rhls"): 2_039_174, ("binsearch", "rhls_stream"): 21_364,
    ("binsearch", "rhls_dec"): 21_354,
    ("binsearch_for", "vitis"): 2_357_243,
    ("binsearch_for", "vitis_dec"): 83_937,
    ("binsearch_for", "rhls"): 2_163_106,
    ("binsearch_for", "rhls_stream"): 22_230,
    ("binsearch_for", "rhls_dec"): 22_206,
    ("hashtable", "vitis"): 1_953_903, ("hashtable", "vitis_dec"): 53_887,
    ("hashtable", "rhls"): 1_687_760, ("hashtable", "rhls_stream"): 19_292,
    ("hashtable", "rhls_dec"): 19_086,
    ("mergesort", "vitis"): 259_157, ("mergesort", "vitis_dec"): 145_423,
    ("mergesort", "rhls"): 199_862, ("mergesort", "rhls_dec"): 7_038,
    ("mergesort_opt", "rhls_dec"): 3_960,
    ("multispmv", "vitis"): 348_343, ("multispmv", "vitis_dec"): 60_243,
    ("multispmv", "rhls"): 71_214, ("multispmv", "rhls_stream"): 32_218,
    ("multispmv", "rhls_dec"): 21_904,
    ("spmv", "vitis"): 286_379, ("spmv", "vitis_dec"): 55_071,
    ("spmv", "rhls"): 18_644, ("spmv", "rhls_stream"): 17_532,
    ("spmv", "rhls_dec"): 17_530,
}


def run(csv_print) -> dict:
    results = {}
    vitis_cycles = {}
    for bench in BENCHMARKS:
        for config in CONFIGS:
            try:
                r = run_workload(bench, config, scale="paper", latency=100,
                                 rif=128)
                cycles = r.cycles
                assert r.correct, f"{bench}/{config} incorrect"
            except DeadlockError:
                cycles = -1  # paper: R-HLS Stream mergesort deadlocks
            results[(bench, config)] = cycles
            if config == "vitis":
                vitis_cycles[bench] = cycles
            paper = PAPER_TABLE1.get((bench, config), 0)
            speedup = (vitis_cycles[bench] / cycles
                       if cycles > 0 and bench in vitis_cycles else 0)
            ratio = cycles / paper if paper and cycles > 0 else 0
            csv_print(f"table1/{bench}/{config},{cycles},"
                      f"speedup_vs_vitis={speedup:.2f};sim_vs_paper="
                      f"{ratio:.2f};paper={paper}")
    return results
