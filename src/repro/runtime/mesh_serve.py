"""Mesh-sharded decoupled serving: the paged pipeline over devices.

:class:`ShardedPagedServeLoop` is :class:`~repro.runtime.serve_loop.
PagedServeLoop` with its engines *placed*: the KV page pool shards its
page dim over the decode mesh's ``data`` axis (``_PAGED_POOL`` rule in
``parallel/sharding.py`` plus the in-jit ``_pool_constraint`` in
``models/attention.py``), page tables ride
:func:`~repro.parallel.sharding.page_table_sharding`, and the
engine-joining channels become
:class:`~repro.channels.mesh.MeshChannel` rings — control messages
physically travel the mesh via collective_permute.

Two placements (:func:`~repro.launch.mesh.make_serve_meshes`):

  * **co-located** — one mesh runs both engines; n=1 degenerates to a
    computation bit-identical to ``PagedServeLoop`` (pinned per
    attention family by tests/test_sharded_serve.py and the
    ``serve/sharded/mesh1`` bench cell).
  * **disaggregated** — Access (prefill) and Execute (decode) run on
    disjoint submeshes joined *only* by mesh channels over the union
    mesh's ``role`` axis.  Prefill writes a private staging pool sized
    ``1 + b*npb`` (a concurrent prefill can never run it dry); on
    prompt completion the slot's pages migrate to the decode pool in
    pool layout — gather on the prefill mesh, host hop, scatter on the
    decode mesh (``bundle.gather_pages``/``scatter_pages``), padded to
    ``npb`` with trash page 0 so one jit covers every prompt length.
    If the decode pool cannot back the migration even after preemption
    escalation, the slot preempts *itself* and re-enters admission
    (teacher-forced resume keeps outputs bit-identical).  Prefix reuse
    is forced off: staging pages are transient, so cross-request
    sharing would dangle across the migration.

Families without paged primitives (recurrent state) keep the
contiguous shared-cache path of the base class — both engines then
drive one dense cache and only the control channels are mesh-placed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.channels import LocalChannel, MeshChannel
from repro.core.trace import Tracer
from repro.launch.mesh import ServeMeshes, make_serve_meshes
from repro.models.registry import build_model
from repro.parallel.sharding import (ShardingRules, cache_shardings,
                                     page_table_sharding, param_shardings)
from repro.runtime.serve_loop import (PageAllocator, PagedServeLoop,
                                      _shared_jit)

__all__ = ["ShardedPagedServeLoop"]


class ShardedPagedServeLoop(PagedServeLoop):
    """Paged decoupled serving with device placement (module docstring).

    ``meshes`` defaults to a single-device co-located placement (the
    bit-parity configuration); ``rules`` default to replicated params
    and no sequence sharding — serving shards the *pool*, and keeping
    params whole makes the sharded loop's outputs exactly match the
    single-host loop's.
    """

    def __init__(self, cfg, bundle, params, batch_slots: int, s_max: int,
                 meshes: Optional[ServeMeshes] = None,
                 rules: Optional[ShardingRules] = None, **kw):
        self.meshes = meshes if meshes is not None else make_serve_meshes(1)
        self.rules = rules if rules is not None else \
            ShardingRules(fsdp=False, seq_shard_cache=False)
        self._disagg = self.meshes.disaggregated
        self._engine = "execute"
        if self._disagg:
            kw["prefix_reuse"] = False
        dm_size = int(np.prod(list(self.meshes.decode.shape.values())))
        self._place = dm_size > 1
        if self._place and cfg.mesh_pool_axis is None:
            cfg = dataclasses.replace(cfg, mesh_pool_axis=self.meshes.axis)
            bundle = build_model(cfg)
        super().__init__(cfg, bundle, params, batch_slots, s_max, **kw)

    # -- placement -----------------------------------------------------------

    def _make_channels(self) -> None:
        self.admit_q = LocalChannel("admit", self._admit_capacity,
                                    self.tracer)
        if self._disagg:
            um, ax = self.meshes.union, self.meshes.role_axis
            self.handoff = MeshChannel("prefill_done", self.b, um, ax,
                                       src=0, dst=1, tracer=self.tracer)
            self.free_slots = MeshChannel("free_slots", self.b, um, ax,
                                          src=1, dst=0, tracer=self.tracer)
        else:
            dm = self.meshes.decode
            span = int(dm.shape[self.meshes.axis])
            self.handoff = MeshChannel("prefill_done", self.b, dm,
                                       self.meshes.axis, src=0,
                                       dst=span - 1, tracer=self.tracer)
            self.free_slots = MeshChannel("free_slots", self.b, dm,
                                          self.meshes.axis, src=span - 1,
                                          dst=0, tracer=self.tracer)

    def _make_cache(self) -> None:
        super()._make_cache()
        if not self.paged:
            return
        dm = self.meshes.decode
        if self._place:
            self.params = jax.device_put(
                self.params, param_shardings(self.params, dm, self.rules))
            self.cache = jax.device_put(
                self.cache, cache_shardings(self.cache, dm, self.rules))
            self._table_sh = page_table_sharding(dm, self.b, self.rules)
        if self._disagg:
            pm = self.meshes.prefill
            self._params_pf = jax.device_put(
                self.params, param_shardings(self.params, pm, self.rules))
            # staging pool: every slot holds at most npb pages, so
            # 1 + b*npb (trash page + b horizons) can never run dry
            self.n_pages_pf = 1 + self.b * self.npb
            self.alloc_pf = PageAllocator(self.n_pages_pf, self.page)
            self.table_pf = np.zeros((self.b, self.npb), np.int32)
            self.n_blocks_pf = np.zeros(self.b, np.int64)
            self.cache_pf = self.bundle.cache_init_paged(
                self.b, self.n_pages_pf, self.page)
            self.cache_pf = jax.device_put(
                self.cache_pf,
                cache_shardings(self.cache_pf, pm, self.rules))
            self._gather = _shared_jit(self.bundle.gather_pages)
            self._scatter = _shared_jit(self.bundle.scatter_pages)

    # -- engine routing ------------------------------------------------------

    def _prefill_step(self, t0, results) -> None:
        self._engine = "access"
        try:
            super()._prefill_step(t0, results)
        finally:
            self._engine = "execute"

    def _step(self, tok, n_valid):
        if not self.paged:
            return super()._step(tok, n_valid)
        if self._disagg and self._engine == "access":
            saved = (self.params, self.cache, self.table)
            self.params = self._params_pf
            self.cache = self.cache_pf
            self.table = self.table_pf
            try:
                with self.meshes.prefill:
                    return super()._step(tok, n_valid)
            finally:
                self.cache_pf = self.cache
                self.params, self.cache, self.table = saved
        tbl = self.table
        if self._place:
            self.table = jax.device_put(np.asarray(tbl), self._table_sh)
        try:
            with self.meshes.decode:
                return super()._step(tok, n_valid)
        finally:
            self.table = tbl

    # -- disaggregated page life cycle ---------------------------------------

    def _release_pf(self, slot: int) -> None:
        for i in range(int(self.n_blocks_pf[slot])):
            self.alloc_pf.decref(int(self.table_pf[slot, i]))
            self.table_pf[slot, i] = 0
        self.n_blocks_pf[slot] = 0

    def _prefill_grant(self, slot: int, ptr: int, n: int) -> int:
        if not (self.paged and self._disagg):
            return super()._prefill_grant(slot, ptr, n)
        if n <= 0:
            return n
        last_blk = (ptr + n - 1) // self.page
        while self.n_blocks_pf[slot] <= last_blk:
            pg = self.alloc_pf.alloc()
            assert pg is not None, "staging pool sized to never run dry"
            self.table_pf[slot, int(self.n_blocks_pf[slot])] = pg
            self.n_blocks_pf[slot] += 1
            self.stats.page_allocs += 1
        return n

    def _on_prompt_complete(self, slot: int) -> None:
        if not (self.paged and self._disagg):
            return super()._on_prompt_complete(slot)
        # migrate the finished prompt's staging pages into the decode
        # pool; on failure the slot preempts itself (the base
        # _prefill_step guard skips its handoff)
        nb = int(self.n_blocks_pf[slot])
        dst: List[int] = []
        for _ in range(nb):
            # _alloc_page may preempt *other* (strictly younger) slots;
            # this slot's staging pages and phase are untouched by that
            pg = self._alloc_page(slot)
            if pg is None:
                for p in dst:
                    self.alloc.decref(p)
                self._preempt(slot)
                return
            dst.append(pg)
        src = [int(self.table_pf[slot, i]) for i in range(nb)]
        self._migrate(src, dst, slot, int(self.pos[slot]))
        for i, p in enumerate(dst):
            self.table[slot, i] = p
        self.n_blocks[slot] = nb
        self._release_pf(slot)

    def _migrate(self, src: List[int], dst: List[int], slot: int,
                 new_len: int) -> None:
        """Move pages ``src`` (staging pool) to ``dst`` (decode pool)
        in pool layout, padded to ``npb`` with trash page 0 (reading
        page 0 is garbage that is never attended; writing it is
        allowed by definition)."""
        pad = self.npb - len(src)
        src_a = jnp.asarray(src + [0] * pad, jnp.int32)
        dst_a = jnp.asarray(dst + [0] * pad, jnp.int32)
        with self.meshes.prefill:
            blocks = self._gather(self.cache_pf, src_a)
        blocks = jax.device_get(blocks)          # prefill -> decode hop
        with self.meshes.decode:
            self.cache = self._scatter(self.cache, blocks, dst_a,
                                       np.int32(slot), np.int32(new_len))
        self.stats.migrations += 1

    def _preempt(self, victim: int) -> None:
        if self.paged and self._disagg:
            self._release_pf(victim)
        super()._preempt(victim)

    def _reset_slots(self, reset, keep, new_lens) -> None:
        if self.paged and self._disagg:
            self.table_pf[reset, :] = 0          # freed rows stay zeroed
            self.cache_pf = self._reset_paged(
                self.cache_pf, jnp.asarray(keep),
                jnp.asarray(new_lens, jnp.int32))
        super()._reset_slots(reset, keep, new_lens)
