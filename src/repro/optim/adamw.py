"""AdamW with global-norm clipping — minimal, pytree-native.

Optimizer state shards exactly like the params (the m/v trees inherit
the param shardings), which is what makes FSDP memory math work.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params: Any) -> OptState:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                             params)
        return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                        v=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads: Any, state: OptState, params: Any
               ) -> Tuple[Any, OptState, jnp.ndarray]:
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf))
                         + 1e-16)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / gnorm)
            gf = jax.tree.map(lambda g: g * scale, gf)

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, gf)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, gf)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            mh = mm / bc1
            vh = vv / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * \
                p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, OptState(step=step, m=m, v=v), gnorm
