"""Atomic pytree (de)serialization.

Arrays are gathered to host, written to a temp file, then renamed —
readers never see a partial checkpoint (crash-consistent).  Leaf paths
are flattened to string keys; metadata (step, anything JSON) rides in a
sidecar entry.  On load, arrays are ``device_put`` against the given
shardings, which is what makes restarts *elastic*: the saved checkpoint
is mesh-agnostic and reshards onto whatever mesh the restarted job has.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Flatten to npz-safe arrays; dtypes npz can't store natively
    (bfloat16, fp8) ride as uint views + a dtype sidecar."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind not in "biufc":     # ml_dtypes etc.
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        flat[key] = arr
    return flat, dtypes


def save_pytree(path: str | Path, tree: Any, meta: Optional[dict] = None
                ) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat, dtypes = _flatten(tree)
    payload = {"meta": meta or {}, "dtypes": dtypes}
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(
                json.dumps(payload).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)          # atomic publish
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_pytree(path: str | Path, like: Any,
                shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs); optionally device_put with ``shardings`` (same
    structure) — elastic resharding happens here."""
    path = Path(path)
    with np.load(path, allow_pickle=False) as z:
        payload = json.loads(bytes(z["__meta__"].tobytes()).decode() or "{}")
        meta = payload.get("meta", payload)
        dtypes = payload.get("dtypes", {})
        flat = {k: z[k] for k in z.files if k != "__meta__"}
    import ml_dtypes  # registered numpy extension dtypes (bf16, fp8)
    for k, dt in dtypes.items():
        if k in flat and str(flat[k].dtype) != dt:
            flat[k] = flat[k].view(np.dtype(dt))

    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_path))
    out = []
    for (path_elems, leaf), sh in zip(leaves_with_path, shard_leaves):
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path_elems)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        if arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), meta
