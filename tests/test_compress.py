"""Int8 wire-format gradient all-reduce (parallel/compress.py):
quantize/dequantize round-trip bounds, error feedback, and the
shard_map use over the data axis (single-device inline; 8-device in a
subprocess, matching tests/test_distributed.py)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.parallel.compress import (compressed_grad_mean, compressed_psum,
                                     dequantize, quantize)

ROOT = Path(__file__).resolve().parents[1]


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(513), jnp.float32)
    q, scale = quantize(g)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    # round-to-nearest against a max-abs/127 scale: error <= scale/2
    err = np.abs(np.asarray(dequantize(q, scale) - g))
    assert err.max() <= float(scale) / 2 + 1e-7
    # the max-magnitude element maps to exactly +-127
    assert int(np.abs(np.asarray(q)).max()) == 127


def test_quantize_handles_zeros():
    q, scale = quantize(jnp.zeros(7, jnp.float32))
    assert np.all(np.asarray(q) == 0) and float(scale) > 0.0


def _run_psum_1dev(g, residual):
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    fn = _shard_map(lambda gg, rr: compressed_psum(gg, rr, "data"),
                    mesh=mesh, in_specs=(P("data"), P("data")),
                    out_specs=(P("data"), P("data")))
    return fn(g, residual)


def test_compressed_psum_single_shard_identity():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((1, 64)), jnp.float32)
    r = jnp.zeros_like(g)
    mean, new_r = _run_psum_1dev(g, r)
    # one participant: mean is dequantize(quantize(g)) and the residual
    # is exactly the quantization error (error feedback invariant)
    np.testing.assert_allclose(np.asarray(mean + new_r), np.asarray(g),
                               rtol=0, atol=1e-6)
    q, scale = quantize(g[0])
    np.testing.assert_allclose(np.asarray(mean[0]),
                               np.asarray(dequantize(q, scale)),
                               rtol=0, atol=1e-6)


def test_error_feedback_reduces_bias_over_steps():
    # feeding the residual forward, repeated reduction of a CONSTANT
    # gradient accumulates toward the true value (unbiasedness over time)
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((1, 32)) * 1e-3, jnp.float32)
    r = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(4):
        mean, r = _run_psum_1dev(g, r)
        total = total + mean
    np.testing.assert_allclose(np.asarray(total), np.asarray(4 * g),
                               rtol=0, atol=float(jnp.abs(g).max()) / 2)


def test_compressed_grad_mean_tree():
    rng = np.random.default_rng(3)
    grads = {"w": jnp.asarray(rng.standard_normal((1, 16)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)}
    res = jax.tree.map(jnp.zeros_like, grads)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    fn = _shard_map(lambda g, r: compressed_grad_mean(g, r, "data"),
                    mesh=mesh,
                    in_specs=(P("data"), P("data")),
                    out_specs=(P("data"), P("data")))
    mean, new_res = fn(grads, res)
    assert set(mean) == {"w", "b"} and set(new_res) == {"w", "b"}
    for k in grads:
        np.testing.assert_allclose(np.asarray(mean[k] + new_res[k]),
                                   np.asarray(grads[k]), rtol=0, atol=1e-6)


@pytest.mark.slow
def test_compressed_allreduce_8_devices():
    """Eight shards with different scales: the int32 payload sum against
    the shared max scale stays close to the exact f32 mean."""
    snippet = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        try:
            from jax import shard_map as _shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map as _shard_map
        from repro.parallel.compress import compressed_psum

        assert jax.device_count() == 8
        mesh = Mesh(np.array(jax.devices()), ("data",))
        rng = np.random.default_rng(0)
        # per-shard gradients with very different magnitudes
        g = rng.standard_normal((8, 256)).astype(np.float32)
        g *= (10.0 ** rng.integers(-2, 3, size=(8, 1))).astype(np.float32)
        r = np.zeros_like(g)
        fn = _shard_map(lambda gg, rr: compressed_psum(gg, rr, "data"),
                        mesh=mesh, in_specs=(P("data"), P("data")),
                        out_specs=(P("data"), P("data")))
        mean, res = fn(jnp.asarray(g), jnp.asarray(r))
        mean = np.asarray(mean)
        exact = g.mean(0, keepdims=True)
        # every shard sees the same reduced mean
        assert np.allclose(mean, np.broadcast_to(mean[:1], mean.shape))
        # int8 wire format against the max scale: per-element error is
        # bounded by ~n_shards * scale_max / (2 * n)
        scale_max = np.abs(g).max() / 127.0
        assert np.abs(mean[0] - exact[0]).max() <= scale_max
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "OK" in out.stdout
