"""End-to-end behaviour of the paper's system: explicit decoupling hides
memory latency across the full stack (programming model -> simulator ->
TPU kernels -> LM framework hooks)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.workloads import run_workload


def test_paper_headline_speedup_band():
    """Table 1's headline: decoupled dynamic HLS gets 10-79x over the
    static baseline at paper scale for a pointer-chasing workload.  We
    check the small-scale band is already >= 10x for hashtable (chains
    are pure latency-bound)."""
    vit = run_workload("hashtable", "vitis", scale="small").cycles
    dec = run_workload("hashtable", "rhls_dec", scale="small").cycles
    assert vit / dec > 10


def test_golden_overhead_small_for_streamed_workload():
    """Fig 4: decoupled designs land near the golden bound once latency
    is hidden (binsearch_for small-scale: bounded overhead)."""
    r = run_workload("binsearch_for", "rhls_dec", scale="small",
                     latency=25, rif=64)
    assert r.overhead < 1.0  # within 2x of the no-latency bound


def test_decoupled_ops_integrate_with_lm():
    """The framework hook: embedding lookup through the decoupled gather
    kernel gives identical results to the XLA path."""
    from repro.configs import get_config
    from repro.models.registry import build_model
    cfg_ref = get_config("chameleon-34b", smoke=True, kernel_mode="ref")
    cfg_dae = get_config("chameleon-34b", smoke=True, kernel_mode="pallas")
    m_ref, m_dae = build_model(cfg_ref), build_model(cfg_dae)
    params = m_ref.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                             cfg_ref.vocab)
    lr = m_ref.apply(params, tok)
    ld = m_dae.apply(params, tok)
    np.testing.assert_allclose(np.asarray(lr, np.float32),
                               np.asarray(ld, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_rif_plan_is_latency_bandwidth_product():
    from repro.core.pipeline import plan_rif
    small = plan_rif(4 * 1024)            # tiny blocks -> many in flight
    big = plan_rif(4 * 1024 * 1024)       # huge blocks -> few buffers
    assert small.rif > big.rif
    assert small.inflight_bytes > 0
