"""Deterministic synthetic LM data — reproducible across restarts.

The stream is indexed by step, so resuming from a checkpoint at step k
regenerates exactly the batches k, k+1, ... (data-state fault tolerance
without storing cursor files).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frames_dim: int = 0          # encdec: also emit frame embeddings

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        r = np.random.default_rng((self.seed, step))
        # Markov-ish stream: mixture of a few "topics" so loss actually falls
        base = r.integers(0, self.vocab, (self.global_batch, 1))
        drift = r.integers(0, max(self.vocab // 64, 2),
                           (self.global_batch, self.seq_len))
        tokens = (base + np.cumsum(drift, axis=1)) % self.vocab
        tokens = tokens.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((self.global_batch, 1), -1, np.int32)],
            axis=1)
        out = {"tokens": tokens, "labels": labels}
        if self.frames_dim:
            out["frames"] = r.standard_normal(
                (self.global_batch, self.seq_len, self.frames_dim)
            ).astype(np.float32)
        return out

    def iter_from(self, step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1
