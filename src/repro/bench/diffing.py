"""Regression diff between a fresh BENCH report and a committed baseline.

Two signals with two disciplines:

  * **cycles** (and integer ``derived`` values, and ``status``) come
    from the pure-Python simulator — deterministic across machines, so
    *any* change is a finding.  A faster cycle count still fails the
    gate: an unexplained improvement is a model change that needs a
    deliberate baseline refresh, not a free win.
  * **us_warm** is wall-clock — environment-dependent, so it gates only
    on slowdowns past ``wall_pct`` percent (CI uses a deliberately
    lenient band; the tight signal is cycles).  ``us_cold`` is recorded
    but never gated: first-call JIT time is too noisy to pin.

Intentional changes go through the allowlist: ``fnmatch`` patterns
(one per line, ``#`` comments) matched against ``axis/cell-name``.
An allowlisted finding is still reported — as a note, not a failure —
so the diff output stays an honest changelog.  Cells *removed* from
the fresh run fail the gate outright: silently shrinking coverage is
the failure mode the matrix exists to prevent.
"""

from __future__ import annotations

import dataclasses
from fnmatch import fnmatchcase
from typing import Dict, Iterable, List, Sequence, Tuple

__all__ = ["Finding", "FAIL_KINDS", "diff_reports", "parse_allowlist",
           "regressions"]

# finding kinds that fail the gate (unless allowlisted)
FAIL_KINDS = ("mode", "removed-cell", "status", "cycles", "wall-clock",
              "derived", "coords")
NOTE_KINDS = ("new-cell", "wall-clock-improved")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One named-cell difference between baseline and fresh run."""

    axis: str
    cell: str
    kind: str
    detail: str
    allowed: bool = False

    @property
    def fails(self) -> bool:
        return self.kind in FAIL_KINDS and not self.allowed

    def render(self) -> str:
        tag = "ALLOWED" if self.allowed else (
            "FAIL" if self.kind in FAIL_KINDS else "note")
        return f"[{tag}] {self.axis}/{self.cell}: {self.kind} — {self.detail}"


def parse_allowlist(text: str) -> Tuple[str, ...]:
    """Allowlist file format: one fnmatch pattern per line, ``#`` comments."""
    out: List[str] = []
    for line in text.splitlines():
        line = line.split("#", 1)[0].strip()
        if line:
            out.append(line)
    return tuple(out)


def _allowed(axis: str, cell: str, patterns: Sequence[str]) -> bool:
    key = f"{axis}/{cell}"
    return any(fnmatchcase(key, pat) for pat in patterns)


def _cells_by_name(report: Dict) -> Dict[str, Dict]:
    return {c["name"]: c for c in report["cells"]}


def diff_reports(baseline: Dict, fresh: Dict, *, wall_pct: float = 25.0,
                 allowlist: Sequence[str] = ()) -> List[Finding]:
    """All findings between two schema-valid reports of the same axis."""
    axis = fresh.get("axis", "?")
    findings: List[Finding] = []

    def add(cell: str, kind: str, detail: str) -> None:
        findings.append(Finding(axis, cell, kind, detail,
                                allowed=_allowed(axis, cell, allowlist)))

    if baseline.get("axis") != fresh.get("axis"):
        add("*", "mode", f"axis mismatch: baseline "
            f"{baseline.get('axis')!r} vs fresh {fresh.get('axis')!r}")
        return findings
    if baseline.get("smoke") != fresh.get("smoke"):
        add("*", "mode", f"smoke mismatch: baseline "
            f"smoke={baseline.get('smoke')} vs fresh "
            f"smoke={fresh.get('smoke')} — compare like against like")
        return findings

    base_cells = _cells_by_name(baseline)
    fresh_cells = _cells_by_name(fresh)
    for name in base_cells:
        if name not in fresh_cells:
            add(name, "removed-cell",
                "present in baseline but missing from the fresh run "
                "(coverage shrank)")
    for name in fresh_cells:
        if name not in base_cells:
            add(name, "new-cell", "not in baseline (refresh to pin it)")

    for name in sorted(set(base_cells) & set(fresh_cells)):
        findings.extend(
            _diff_cell(axis, base_cells[name], fresh_cells[name],
                       wall_pct=wall_pct, allowlist=allowlist))
    return findings


def _diff_cell(axis: str, base: Dict, fresh: Dict, *, wall_pct: float,
               allowlist: Sequence[str]) -> List[Finding]:
    name = base["name"]
    out: List[Finding] = []

    def add(kind: str, detail: str) -> None:
        out.append(Finding(axis, name, kind, detail,
                           allowed=_allowed(axis, name, allowlist)))

    if base["coords"] != fresh["coords"]:
        add("coords", f"coordinates changed: {base['coords']} -> "
            f"{fresh['coords']}")
    if base["status"] != fresh["status"]:
        add("status", f"{base['status']} -> {fresh['status']}")
        return out  # cycle/time comparisons are meaningless across states

    bc, fc = base.get("cycles"), fresh.get("cycles")
    if bc != fc:
        if bc is None or fc is None:
            add("cycles", f"cycles went {bc} -> {fc}")
        else:
            direction = "regressed" if fc > bc else "improved"
            add("cycles", f"{direction}: {bc} -> {fc} "
                f"({fc - bc:+d} cycles; cycle counts are deterministic — "
                f"refresh the baseline if intentional)")

    bw, fw = base.get("us_warm"), fresh.get("us_warm")
    if bw is not None and fw is not None and bw > 0:
        ratio = 100.0 * (fw - bw) / bw
        if ratio > wall_pct:
            add("wall-clock", f"warm time regressed {ratio:.0f}% "
                f"({bw:.1f}us -> {fw:.1f}us, gate {wall_pct:.0f}%)")
        elif ratio < -wall_pct:
            add("wall-clock-improved",
                f"warm time improved {-ratio:.0f}% "
                f"({bw:.1f}us -> {fw:.1f}us)")

    bd, fd = base.get("derived", {}), fresh.get("derived", {})
    for key in sorted(set(bd) | set(fd)):
        b, f = bd.get(key), fd.get(key)
        b_int = isinstance(b, int) and not isinstance(b, bool)
        f_int = isinstance(f, int) and not isinstance(f, bool)
        # ints are deterministic side-channels (channel counts, buffer
        # bytes, golden cycles); floats/strings are informational only
        if (b_int or f_int) and b != f:
            add("derived", f"derived[{key}]: {b!r} -> {f!r}")
    return out


def regressions(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.fails]
