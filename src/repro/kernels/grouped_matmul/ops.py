"""Jit'd wrapper for the grouped expert matmul."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_interpret, round_up, tuned_knobs
from repro.kernels.grouped_matmul import kernel as _k
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref


@functools.partial(jax.jit, static_argnames=("bt", "bf", "bd", "interpret",
                                              "method"))
def _gmm_impl(x, w, block_expert, *, bt, bf, bd, interpret, method):
    if method == "ref":
        return grouped_matmul_ref(x, w, block_expert, bt)
    t, d = x.shape
    e, _, f = w.shape
    dp, fp = round_up(d, bd), round_up(f, bf)
    if dp != d:
        x = jnp.pad(x, ((0, 0), (0, dp - d)))
        w = jnp.pad(w, ((0, 0), (0, dp - d), (0, 0)))
    if fp != f:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, fp - f)))
    out = _k.gmm(x, w, block_expert.astype(jnp.int32), bt=bt, bf=bf, bd=bd,
                 interpret=interpret)
    return out[:, :f]


def grouped_matmul(x: jax.Array, w: jax.Array, block_expert: jax.Array, *,
                   bt: int = 128, bf: Optional[int] = None,
                   bd: Optional[int] = None, method: str = "pallas",
                   interpret: Optional[bool] = None) -> jax.Array:
    """Expert-grouped GEMM: x (T, D) with tokens sorted by expert and
    padded so groups align to ``bt``; block_expert (T//bt,) is the expert
    of each token block; w (E, D, F).  Returns (T, F).

    ``bf``/``bd`` left ``None`` resolve via the tune cache (128/512)."""
    t, d = x.shape
    if t % bt:
        raise ValueError(f"T={t} must be a multiple of bt={bt}")
    interp = resolve_interpret(interpret)
    if bf is None or bd is None:
        knobs = tuned_knobs("grouped_matmul", (t, d, w.shape[2]), x.dtype,
                            interp, bf=(bf, 128), bd=(bd, 512))
        bf, bd = knobs["bf"], knobs["bd"]
    bd = min(bd, round_up(d, 128))
    bf = min(bf, round_up(w.shape[2], 128))
    return _gmm_impl(x, w, block_expert, bt=bt, bf=bf, bd=bd,
                     interpret=interp, method=method)
