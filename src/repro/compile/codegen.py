"""Pass 4 — codegen: instantiate ring kernels + the store epilogue.

The checked IR lowers onto the three templates in
:mod:`repro.kernels.compiled`:

  * every surviving STATIC channel   -> one :func:`ring_gather` call;
  * every INDIRECT channel + source  -> one :func:`ring_deref` call
    (the source's landed values come back from phase 1);
  * a ChaseSpec program              -> one :func:`ring_chase` call.

What remains on the host is the *store epilogue*: the traced
:class:`~repro.compile.ir.StoreIR` events replayed in program order,
each copy store reading its channel's landed row, each const store its
partially-evaluated value.  That replay is pure bookkeeping — every
byte that moves, moves through a ring on the device.

Each kernel invocation is wrapped in ``jax.jit`` once at compile time,
so repeated :meth:`CompiledKernel.__call__`\\ s (the bench loop) pay no
retrace.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile.check import CheckResult, _norm_value
from repro.compile.infer import ChannelPlan
from repro.compile.ir import ChannelIR, ChaseSpec, DaeIR, StreamKind
from repro.kernels.compiled import ring_chase, ring_deref, ring_gather

__all__ = ["CompiledKernel", "codegen"]


def _padded_addrs(addrs: List[int], chunk: int) -> np.ndarray:
    m = len(addrs)
    mp = -(-m // chunk) * chunk
    out = np.zeros(mp, np.int32)          # pad fetches row 0; sliced off
    out[:m] = addrs
    return out


def _gather_runner(ir: DaeIR, c: ChannelIR, plan: ChannelPlan,
                   interpret: bool) -> Callable[[], Dict[str, Any]]:
    port_j = jnp.asarray(ir.ports[c.port].array)
    addrs_j = jnp.asarray(_padded_addrs(c.addrs, plan.chunk))
    fn = jax.jit(functools.partial(ring_gather, chunk=plan.chunk,
                                   rif=plan.rif, interpret=interpret))
    name, m = c.name, c.count

    def run() -> Dict[str, Any]:
        return {name: np.asarray(fn(port_j, addrs_j))[:m]}
    return run


def _deref_runner(ir: DaeIR, src: ChannelIR, c: ChannelIR,
                  src_plan: ChannelPlan, plan: ChannelPlan,
                  interpret: bool) -> Callable[[], Dict[str, Any]]:
    a_j = jnp.asarray(ir.ports[src.port].array)
    b_j = jnp.asarray(ir.ports[c.port].array)
    chunk = plan.chunk
    addrs_j = jnp.asarray(_padded_addrs(src.addrs, chunk))
    fn = jax.jit(functools.partial(
        ring_deref, chunk=chunk, rif_a=src_plan.rif, rif_b=plan.rif,
        offset=c.offset, interpret=interpret))
    names, m = (src.name, c.name), c.count

    def run() -> Dict[str, Any]:
        out_a, out_b = fn(a_j, b_j, addrs_j)
        return {names[0]: np.asarray(out_a)[:m],
                names[1]: np.asarray(out_b)[:m]}
    return run


def _chase_runner(ir: DaeIR, spec: ChaseSpec, plan: ChannelPlan,
                  interpret: bool) -> Callable[[], Dict[str, Any]]:
    m, s = spec.n_items, spec.state_width
    chunk = max(1, min(plan.chunk, m))     # plan.chunk sized on requests
    rif = max(1, min(plan.rif, chunk))     # = items x levels; re-clamp
    mp = -(-m // chunk) * chunk
    state0 = np.zeros((mp, s), np.int32)
    state0[:m] = spec.state0.astype(np.int32)
    if mp > m:
        state0[m:] = state0[0]             # pad items shadow item 0
    port_j = jnp.asarray(ir.ports[spec.port].array)
    flat_j = jnp.asarray(state0.reshape(-1))
    fn = jax.jit(functools.partial(
        ring_chase, chunk=chunk, rif=rif, max_steps=spec.max_steps,
        s_width=s, addr_fn=spec.addr_fn, step_fn=spec.step_fn,
        out_fn=spec.out_fn, interpret=interpret))

    def run() -> Dict[str, Any]:
        oa, ov = fn(port_j, flat_j)
        return {"__chase__": (np.asarray(oa)[:m], np.asarray(ov)[:m])}
    return run


@dataclasses.dataclass
class CompiledKernel:
    """A runnable compiled program: call it, get the output ports.

    ``__call__`` runs every ring kernel (device), then the store
    epilogue (host), and returns ``{out port: np.ndarray}`` — width-1
    ports as 1-D arrays, matching what
    :meth:`SimResult.stored_array`-style oracles produce.
    """

    name: str
    shape: str                              # 'gather' | 'deref' | 'chase'
    ir: DaeIR
    plans: Dict[str, ChannelPlan]
    out_specs: Dict[str, Tuple[int, int, Any]]
    interpret: bool
    chase: Optional[ChaseSpec] = None
    runners: List[Callable[[], Dict[str, Any]]] = \
        dataclasses.field(default_factory=list)

    def __call__(self) -> Dict[str, np.ndarray]:
        landed: Dict[str, Any] = {}
        for run in self.runners:
            landed.update(run())

        outs: Dict[str, np.ndarray] = {}
        for port, (length, width, dtype) in self.out_specs.items():
            arr = np.zeros((length, width), dtype)
            raw = self.ir.raw_memories.get(port)
            if raw is not None:            # numeric initial contents
                for i, v in enumerate(raw):
                    row = _norm_value(v)
                    if row is not None and len(row) == width:
                        arr[i] = row.astype(dtype)
            outs[port] = arr

        if self.shape == "chase":
            if "__chase__" in landed:
                oa, ov = landed["__chase__"]
                out = outs[self.chase.out_port]
                for a, v in zip(oa, ov):
                    out[int(a), 0] = v
        else:
            for st in self.ir.stores:
                if st.source is not None:
                    cname, k = st.source
                    val = landed[cname][k]
                else:                       # const: partially evaluated
                    val = _norm_value(st.value)
                outs[st.port][st.addr] = np.asarray(val).astype(
                    outs[st.port].dtype)

        return {p: (a[:, 0] if a.shape[1] == 1 else a)
                for p, a in outs.items()}

    def describe(self) -> str:
        lines = [f"CompiledKernel({self.name}) shape={self.shape} "
                 f"interpret={self.interpret}"]
        for p in self.plans.values():
            lines.append(f"  plan {p.channel}: chunk={p.chunk} "
                         f"rif={p.rif} [{p.source}]"
                         + (f" ({p.note})" if p.note else ""))
        lines.append(self.ir.describe())
        return "\n".join(lines)


def codegen(ir: DaeIR, chk: CheckResult,
            plans: Dict[str, ChannelPlan], *,
            chase: Optional[ChaseSpec] = None,
            interpret: bool = True) -> CompiledKernel:
    """Instantiate the ring kernels for a checked IR."""
    runners: List[Callable[[], Dict[str, Any]]] = []

    if chk.shape == "chase":
        assert chase is not None
        if ir.channels and chase.n_items > 0:
            (c,) = ir.channels.values()
            runners.append(_chase_runner(ir, chase, plans[c.name],
                                         interpret))
    else:
        consumed = set()
        for c in ir.channels.values():
            if c.kind is StreamKind.INDIRECT and c.count > 0:
                src = ir.channels[c.source]
                runners.append(_deref_runner(
                    ir, src, c, plans[src.name], plans[c.name],
                    interpret))
                consumed.update((src.name, c.name))
        for c in ir.channels.values():
            if (c.name not in consumed
                    and c.kind is StreamKind.STATIC and c.count > 0):
                runners.append(_gather_runner(ir, c, plans[c.name],
                                              interpret))

    return CompiledKernel(
        name=ir.name, shape=chk.shape, ir=ir, plans=plans,
        out_specs=chk.out_specs, interpret=interpret, chase=chase,
        runners=runners)
