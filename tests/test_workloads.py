"""The seven paper benchmarks: correctness in every config + the paper's
qualitative orderings (Table 1 structure) at small scale."""

import pytest

from repro.core.simulator import DeadlockError
from repro.core.workloads import BENCHMARKS, CONFIGS, run_workload

SMALL = dict(scale="small", latency=100, rif=128)


@pytest.mark.parametrize("bench", BENCHMARKS)
@pytest.mark.parametrize("config", CONFIGS)
def test_correct_all_cells(bench, config):
    if config == "rhls_stream" and bench.startswith("mergesort"):
        with pytest.raises(DeadlockError):
            run_workload(bench, config, **SMALL)
        return
    r = run_workload(bench, config, **SMALL)
    assert r.correct, f"{bench}/{config} produced wrong results"
    assert r.cycles > 0
    assert r.golden > 0


@pytest.mark.parametrize("bench", ["binsearch", "hashtable", "spmv"])
def test_decoupling_speedup_ordering(bench):
    """vitis > vitis_dec > ~rhls_dec in cycles (paper Table 1)."""
    vit = run_workload(bench, "vitis", **SMALL).cycles
    vdec = run_workload(bench, "vitis_dec", **SMALL).cycles
    rdec = run_workload(bench, "rhls_dec", **SMALL).cycles
    assert vit > vdec > 0
    assert vdec >= rdec


def test_decoupled_binsearch_hides_latency():
    """Cycles should track iterations, not iterations x latency — needs
    enough concurrent chains (paper scale: 1000 lookups >= latency)."""
    r100 = run_workload("binsearch", "rhls_dec", scale="paper", latency=100,
                        rif=128)
    r400 = run_workload("binsearch", "rhls_dec", scale="paper", latency=400,
                        rif=512)
    # 4x latency costs far less than 4x cycles once decoupled
    assert r400.cycles < 1.5 * r100.cycles


def test_rif_sweep_monotone():
    """More requests in flight -> fewer cycles until latency is covered
    (the paper's 'as many lookups in parallel as the latency' rule)."""
    cycles = [run_workload("hashtable", "rhls_dec", scale="small",
                           latency=100, rif=rif).cycles
              for rif in (2, 8, 32, 128)]
    assert cycles[0] > cycles[1] > cycles[2] >= cycles[3]


def test_moms_memory_mode_runs():
    r = run_workload("binsearch", "rhls_dec", scale="small", mem="moms")
    assert r.correct


def test_mergesort_opt_saves_cycles():
    plain = run_workload("mergesort", "rhls_dec", **SMALL).cycles
    opt = run_workload("mergesort_opt", "rhls_dec", **SMALL).cycles
    assert opt < plain
