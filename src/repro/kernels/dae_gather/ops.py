"""Jit'd public wrapper for the decoupled gather kernel.

Handles shape padding, method dispatch, and the ref fallback used by the
dry-run path (``method='ref'``) where the compiled HLO must reflect the
XLA gather the roofline accounts for.

Knobs left at ``None`` resolve through ``repro.tune``: a cached tuned
config for this (shape, dtype, backend) wins, otherwise the analytic
``plan_rif`` latency×bandwidth plan sizes the ring.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import (cdiv, resolve_interpret, ring_rif,
                                  round_up, tuned_knobs)
from repro.kernels.dae_gather import kernel as _k
from repro.kernels.dae_gather.ref import gather_ref


@functools.partial(
    jax.jit,
    static_argnames=("method", "block_d", "chunk", "rif", "interpret"))
def _dae_gather_impl(table, idx, *, method, block_d, chunk, rif, interpret):
    n, d = table.shape
    m = idx.shape[0]
    idx = idx.astype(jnp.int32)

    if method == "ref":
        return gather_ref(table, idx)

    # pad the feature dim to the lane granularity the kernels require
    dp = round_up(d, 128)
    if dp != d:
        table = jnp.pad(table, ((0, 0), (0, dp - d)))

    if method == "pipelined":
        bd = block_d or min(dp, 512)
        bd = dp // max(1, dp // bd)  # ensure divisibility
        while dp % bd:
            bd -= 1
        out = _k.gather_pipelined(table, idx, block_d=bd, interpret=interpret)
    elif method == "rif":
        c = min(chunk, m) or 1
        mp = round_up(m, c)
        if mp != m:
            idx = jnp.pad(idx, (0, mp - m))
        out = _k.gather_rif(table, idx, chunk=c, rif=min(rif, c),
                            interpret=interpret)
        out = out[:m]
    else:
        raise ValueError(f"unknown method {method!r}")

    return out[:, :d]


def dae_gather(
    table: jax.Array,
    idx: jax.Array,
    *,
    method: Optional[str] = None,
    block_d: Optional[int] = None,
    chunk: Optional[int] = None,
    rif: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Decoupled gather of ``table`` (N, D) rows at ``idx`` (M,) -> (M, D).

    method='pipelined': scalar-prefetch indexed BlockSpec (RIF = pipeline
    double-buffering); method='rif': explicit multi-buffer DMA ring with
    ``rif`` requests in flight; method='ref': jnp oracle (XLA gather).

    Knobs left ``None`` resolve via the tune cache, then ``plan_rif``.
    """
    interp = resolve_interpret(interpret)
    n, d = table.shape
    if method is None or block_d is None or chunk is None or rif is None:
        knobs = tuned_knobs("dae_gather", (n, d, idx.shape[0]), table.dtype,
                            interp, method=(method, "pipelined"),
                            block_d=(block_d, None), chunk=(chunk, 64),
                            rif=(rif, None))
        method, block_d, chunk = knobs["method"], knobs["block_d"], \
            knobs["chunk"]
        dp = round_up(max(d, 1), 128)
        rif = ring_rif(knobs["rif"], chunk * dp * table.dtype.itemsize)
    return _dae_gather_impl(table, idx, method=method, block_d=block_d,
                            chunk=chunk, rif=rif, interpret=interp)
