"""Tuned-vs-untuned dispatch over EVERY ``KERNEL_DIMS`` op.

The PR 5 SPMV bug class: a tuner persists a winner under one cache key,
but the dispatcher's ``None``-knob lookup happens under *different*
dims (SPMV stores at CSR dims, ``dae_spmv`` looks up at the converted
BSR dims), so the tuned config silently never dispatches and the
analytic ``plan_rif`` fallback runs instead.  Wall-clock benchmarks
cannot catch that — the fallback also works, just slower.

This file closes the class structurally:

  * one spy case per ``KERNEL_DIMS`` op (a completeness test pins the
    set, so adding an op without dispatch coverage fails CI);
  * each case runs the same call twice — cold cache (untuned), then
    with a distinctively-knobbed ``CacheEntry`` planted under the
    *canonical* key — and asserts at the ``_k.<kernel>`` seam that the
    planted knobs actually reach the kernel, and that they *differ*
    from the untuned run (no vacuous pass when a default happens to
    equal the plant);
  * the SPMV case plants a decoy ``rif`` under the CSR key and the
    real one only under ``measure.alias_keys`` (the BSR mirror), so a
    regression that re-introduces the wrong-key lookup is caught by
    value, not by absence.

Everything runs in interpret mode at tiny odd shapes (fresh jit traces,
so the spies fire at trace time with the static knob values).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.tune import CacheEntry, default_cache
from repro.tune.cache import make_key
from repro.tune.runners import KERNEL_DIMS, backend_tag, kernel_runner


def _plant(op, dims, dtype, config):
    key = make_key(op, dims, dtype, backend_tag(True), "wallclock")
    default_cache().put(key, CacheEntry(config=dict(config), score=1.0))


def _spy(monkeypatch, module, name, record, keys):
    """Wrap ``module.<name>`` to record the knob kwargs in ``keys``."""
    real = getattr(module, name)

    def spy(*a, **kw):
        record.append({k: kw[k] for k in keys})
        return real(*a, **kw)

    monkeypatch.setattr(module, name, spy)


def _fresh_traces(*jitted):
    """Spies fire at trace time; drop any executable another test cached
    for the same (shapes, statics) so every call here retraces."""
    for fn in jitted:
        fn.clear_cache()


def _tuned_untuned(call, plant, record):
    """Run ``call`` cold-cache, then with ``plant()`` applied; return the
    last-recorded knobs of each run."""
    call()
    assert record, "spy never fired on the untuned call (stale jit trace?)"
    untuned = record[-1]
    plant()
    record.clear()
    call()
    assert record, "spy never fired on the tuned call (stale jit trace?)"
    return record[-1], untuned


# -- one case per op ----------------------------------------------------------
#
# Each case returns (tuned, untuned, expected): the knob dicts the spy saw
# and the planted knobs after dispatcher-side coercions.


def _case_dae_gather(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.dae_gather.ops as ops
    n, d, m = 112, 128, 48
    _fresh_traces(ops._dae_gather_impl)
    r = np.random.default_rng(0)
    table = jnp.asarray(r.standard_normal((n, d)), jnp.float32)
    idx = jnp.asarray(r.integers(0, n, m), jnp.int32)
    rec = []
    _spy(monkeypatch, ops._k, "gather_rif", rec, ("chunk", "rif"))
    # the cold-cache default is method='pipelined'; spy that seam too so
    # the untuned run records *something* comparable
    _spy(monkeypatch, ops._k, "gather_pipelined", rec, ("block_d",))
    tuned, untuned = _tuned_untuned(
        lambda: ops.dae_gather(table, idx, interpret=True),
        lambda: _plant("dae_gather", (n, d, m), "float32",
                       {"method": "rif", "chunk": 16, "rif": 5}),
        rec)
    return tuned, untuned, {"chunk": 16, "rif": 5}


def _case_dae_merge(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.dae_merge.ops as ops
    n, m = 88, 72
    _fresh_traces(ops._merge_impl)
    r = np.random.default_rng(0)
    a = jnp.sort(jnp.asarray(r.standard_normal(n), jnp.float32))
    b = jnp.sort(jnp.asarray(r.standard_normal(m), jnp.float32))
    rec = []
    _spy(monkeypatch, ops._k, "merge_tiles", rec, ("tile", "rif"))
    tuned, untuned = _tuned_untuned(
        lambda: ops.merge_sorted(a, b, interpret=True),
        lambda: _plant("dae_merge", (n, m), "float32",
                       {"tile": 32, "rif": 3}),
        rec)
    # tile 32 is already a power of two, so the bitonic coercion is a no-op
    return tuned, untuned, {"tile": 32, "rif": 3}


def _case_flash_attention(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.flash_attention.ops as ops
    sq, sk, d = 48, 80, 64
    _fresh_traces(ops._flash_impl)
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((1, 4, sq, d)), jnp.float32)
    k = jnp.asarray(r.standard_normal((1, 2, sk, d)), jnp.float32)
    v = jnp.asarray(r.standard_normal((1, 2, sk, d)), jnp.float32)
    rec = []
    _spy(monkeypatch, ops._k, "flash", rec, ("bq", "bk"))
    tuned, untuned = _tuned_untuned(
        lambda: ops.flash_attention(q, k, v, interpret=True),
        lambda: _plant("flash_attention", (sq, sk, d), "float32",
                       {"bq": 16, "bk": 16}),
        rec)
    return tuned, untuned, {"bq": 16, "bk": 16}


def _case_flash_decode(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.flash_attention.ops as ops
    s, d = 96, 64
    _fresh_traces(ops._decode_impl)
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((1, 2, d)), jnp.float32)
    kc = jnp.asarray(r.standard_normal((1, 1, s, d)), jnp.float32)
    vc = jnp.asarray(r.standard_normal((1, 1, s, d)), jnp.float32)
    lens = jnp.asarray([s], jnp.int32)
    rec = []
    _spy(monkeypatch, ops._k, "flash_decode", rec, ("bk", "rif"))
    tuned, untuned = _tuned_untuned(
        lambda: ops.flash_decode(q, kc, vc, lens, interpret=True),
        lambda: _plant("flash_decode", (s, d), "float32",
                       {"bk": 32, "rif": 3}),
        rec)
    return tuned, untuned, {"bk": 32, "rif": 3}


def _case_flash_decode_paged(monkeypatch):
    import jax.numpy as jnp
    from repro.core.pipeline import plan_rif
    import repro.kernels.flash_attention.ops as ops
    page, d, npb = 32, 64, 2
    _fresh_traces(ops._decode_paged_impl)
    r = np.random.default_rng(0)
    q = jnp.asarray(r.standard_normal((1, 2, d)), jnp.float32)
    kp = jnp.asarray(r.standard_normal((npb, 1, page, d)), jnp.float32)
    vp = kp + 1.0
    pt = jnp.arange(npb, dtype=jnp.int32).reshape(1, npb)
    lens = jnp.asarray([npb * page], jnp.int32)
    # rif is the only knob: the plant must differ from the analytic
    # fallback or the case proves nothing
    assert plan_rif(page * d * 4).rif != 3
    rec = []
    _spy(monkeypatch, ops._k, "flash_decode_paged", rec, ("rif",))
    tuned, untuned = _tuned_untuned(
        lambda: ops.flash_decode_paged(q, kp, vp, pt, lens, interpret=True),
        lambda: _plant("flash_decode_paged", (page, d), "float32",
                       {"rif": 3}),
        rec)
    return tuned, untuned, {"rif": 3}


def _case_grouped_matmul(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.grouped_matmul.ops as ops
    t, d, f = 128, 256, 256
    _fresh_traces(ops._gmm_impl)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((t, d)), jnp.float32)
    w = jnp.asarray(r.standard_normal((2, d, f)), jnp.float32)
    blk = jnp.zeros((t // 128,), jnp.int32)
    rec = []
    _spy(monkeypatch, ops._k, "gmm", rec, ("bf", "bd", "rif"))
    tuned, untuned = _tuned_untuned(
        lambda: ops.grouped_matmul(x, w, blk, interpret=True),
        lambda: _plant("grouped_matmul", (t, d, f), "float32",
                       {"bf": 64, "bd": 128, "rif": 3}),
        rec)
    # the block plants survive the min(knob, round_up(dim, 128)) clamps
    # at these dims, and explicit-from-cache rif bypasses ring_rif's
    # plan_rif fallback
    return tuned, untuned, {"bf": 64, "bd": 128, "rif": 3}


def _case_batched_searchsorted(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.dae_chase.ops as ops
    n, m = 176, 24
    _fresh_traces(ops._searchsorted_impl)
    r = np.random.default_rng(0)
    table = jnp.sort(jnp.asarray(r.integers(0, 1 << 20, n), jnp.int32))
    keys = jnp.asarray(r.integers(0, 1 << 20, m), jnp.int32)
    rec = []
    _spy(monkeypatch, ops._k, "searchsorted_blocks", rec, ("chunk", "rif"))
    tuned, untuned = _tuned_untuned(
        lambda: ops.batched_searchsorted(table, keys, interpret=True),
        lambda: _plant("batched_searchsorted", (n, m), "int32",
                       {"block": 32, "chunk": 8, "rif": 3}),
        rec)
    return tuned, untuned, {"chunk": 8, "rif": 3}


def _case_hash_lookup(monkeypatch):
    import jax.numpy as jnp
    import repro.kernels.dae_chase.ops as ops
    n, m, chain = 80, 16, 4
    _fresh_traces(ops._hash_lookup_impl)
    r = np.random.default_rng(0)
    ek = jnp.asarray(np.arange(n), jnp.int32)
    ev = jnp.asarray(r.integers(0, 1 << 16, n), jnp.int32)
    en = jnp.asarray([(i + 1) if (i + 1) % chain else -1 for i in range(n)],
                     jnp.int32)
    heads = jnp.asarray(r.integers(0, n // chain, m) * chain, jnp.int32)
    keys = heads + jnp.asarray(r.integers(0, chain, m), jnp.int32)
    rec = []
    _spy(monkeypatch, ops._k, "hash_probe", rec, ("chunk", "rif"))
    tuned, untuned = _tuned_untuned(
        lambda: ops.hash_lookup(ek, ev, en, heads, keys, max_steps=chain,
                                interpret=True),
        lambda: _plant("hash_lookup", (n, m), "int32",
                       {"chunk": 8, "rif": 3}),
        rec)
    return tuned, untuned, {"chunk": 8, "rif": 3}


def _case_dae_spmv(monkeypatch):
    """The alias-key case (the original PR 5 gap, now by value).

    ``csr_to_bsr`` stores/looks up the block shape under the CSR dims;
    ``dae_spmv`` looks up ``rif`` under the *converted* BSR dims that
    only ``measure.alias_keys`` knows how to mirror.  Plant a decoy rif
    under the CSR key and the real one under the alias keys: the spy
    must see the alias value — seeing the decoy (or the ``plan_rif``
    fallback) means the wrong-key lookup came back.
    """
    import jax.numpy as jnp
    from repro.core.pipeline import plan_rif
    import repro.kernels.dae_spmv.ops as ops
    nrows, ncols, nnz = 16, 256, 64
    _fresh_traces(ops._spmv_impl)
    best = {"bm": 4, "bk": 128, "rif": 5}
    assert plan_rif(best["bk"] * 4).rif != best["rif"]

    # same construction as runners._spmv_measure (seed 0), so the BSR
    # dims of this data match what measure.alias_keys mirrors
    r = np.random.default_rng(0)
    counts = r.multinomial(nnz, np.ones(nrows) / nrows)
    rows = np.zeros(nrows + 1, np.int64)
    rows[1:] = np.cumsum(counts)
    cols = r.integers(0, ncols, nnz)
    val = r.standard_normal(nnz).astype(np.float32)
    vec = jnp.asarray(r.standard_normal(ncols), jnp.float32)

    rec = []
    _spy(monkeypatch, ops._k, "bsr_spmv", rec, ("rif",))

    def call():
        vb, ri, ci, _, nrb = ops.csr_to_bsr(rows, cols, val, ncols)
        out = ops.dae_spmv(jnp.asarray(vb), jnp.asarray(ri), jnp.asarray(ci),
                           vec, nrb, interpret=True)
        return vb, out

    # untuned: block shape falls back to (8, 128), rif to plan_rif
    vb_untuned, _ = call()
    assert rec and vb_untuned.shape[1:] == (8, 128)
    untuned = rec[-1]

    measure, _key, _dims = kernel_runner("dae_spmv", (nrows, ncols, nnz),
                                         interpret=True)
    _plant("dae_spmv", (nrows, ncols, nnz), "float32", {**best, "rif": 9})
    for alias in measure.alias_keys(best):
        default_cache().put(alias, CacheEntry(config=dict(best), score=1.0))

    rec.clear()
    vb_tuned, _ = call()
    assert rec, "spy never fired on the tuned call"
    # the planted block shape dispatched through the CSR key...
    assert vb_tuned.shape[1:] == (best["bm"], best["bk"])
    # ...and the rif through the BSR alias key, not the CSR decoy
    assert rec[-1]["rif"] != 9, "rif came from the CSR key (alias-key gap)"
    return rec[-1], untuned, {"rif": best["rif"]}


_CASES = {
    "dae_gather": _case_dae_gather,
    "dae_merge": _case_dae_merge,
    "flash_attention": _case_flash_attention,
    "flash_decode": _case_flash_decode,
    "flash_decode_paged": _case_flash_decode_paged,
    "grouped_matmul": _case_grouped_matmul,
    "batched_searchsorted": _case_batched_searchsorted,
    "hash_lookup": _case_hash_lookup,
    "dae_spmv": _case_dae_spmv,
}


def test_every_kernel_dims_op_has_a_dispatch_case():
    """Adding a tunable op without tuned-dispatch coverage fails here."""
    assert set(_CASES) == set(KERNEL_DIMS)


@pytest.mark.parametrize("op", sorted(_CASES))
def test_tuned_knobs_actually_dispatch(op, monkeypatch):
    tuned, untuned, expected = _CASES[op](monkeypatch)
    assert tuned == expected, (
        f"{op}: planted cache knobs did not reach the kernel "
        f"(got {tuned}, planted {expected})")
    assert tuned != untuned, (
        f"{op}: tuned and untuned runs dispatched identically ({tuned}) — "
        f"the plant is not distinctive, the case proves nothing")
