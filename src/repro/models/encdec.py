"""Encoder-decoder assembly (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D); the transformer backbone
(24L enc + 24L dec in the full config) is real.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.blocks import block_apply, block_cache_init, block_init
from repro.models.common import ModelConfig, cross_entropy_loss, dense_init, \
    rmsnorm, rmsnorm_init
from repro.models.transformer import _stack_init, embed_tokens

Params = Dict[str, Any]


def encdec_init(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "embed": dense_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype),
        "enc": _stack_init(cfg, "enc", cfg.n_enc_layers, ks[1]),
        "enc_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
        "dec": _stack_init(cfg, "xattn", cfg.n_layers, ks[2]),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
        "unembed": dense_init(ks[3], cfg.d_model, cfg.vocab, cfg.pdtype),
    }


def encode(cfg: ModelConfig, params: Params, frames: jnp.ndarray
           ) -> jnp.ndarray:
    """frames (B, S_enc, D) — precomputed modality-frontend embeddings."""
    b, se, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))
    x = frames.astype(cfg.adtype)

    def body(h, layer_params):
        h2, _ = block_apply(cfg, "enc", layer_params, h, positions)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x = _scan_or_unroll(cfg, body, x, params["enc"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _scan_or_unroll(cfg, body, x, stacked):
    from repro.models.transformer import _sp_constraint

    def sp_body(h, layer_params):
        h2, aux = body(_sp_constraint(cfg, h), layer_params)
        return _sp_constraint(cfg, h2), aux

    if not cfg.scan_layers:
        count = jax.tree.leaves(stacked)[0].shape[0]
        for i in range(count):
            x, _ = sp_body(x, jax.tree.map(lambda a: a[i], stacked))
        return x
    x, _ = jax.lax.scan(sp_body, x, stacked)
    return x


def decode_train(cfg: ModelConfig, params: Params, enc_out: jnp.ndarray,
                 tokens: jnp.ndarray) -> jnp.ndarray:
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = embed_tokens(cfg, params, tokens)

    def body(h, layer_params):
        enc_kv = attn.cross_kv(cfg, layer_params["xattn"], enc_out)
        h2, _ = block_apply(cfg, "xattn", layer_params, h, positions,
                            enc_kv=enc_kv)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x = _scan_or_unroll(cfg, body, x, params["dec"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["unembed"].astype(cfg.adtype)


def encdec_loss(cfg: ModelConfig, params: Params,
                batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    enc_out = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, enc_out, batch["tokens"])
    return cross_entropy_loss(logits, batch["labels"])


# -- decode (serving) ---------------------------------------------------------


def encdec_cache_init(cfg: ModelConfig, batch: int, s_max: int) -> Any:
    one = block_cache_init(cfg, "xattn", batch, s_max)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def encdec_decode_step(cfg: ModelConfig, params: Params, enc_out: jnp.ndarray,
                       caches: Any, token: jnp.ndarray, pos: jnp.ndarray
                       ) -> Tuple[jnp.ndarray, Any]:
    b = token.shape[0]
    positions = pos[:, None]
    x = embed_tokens(cfg, params, token[:, None])

    def body(h, pc):
        layer_params, layer_cache = pc
        enc_kv = attn.cross_kv(cfg, layer_params["xattn"], enc_out)
        h2, nc = block_apply(cfg, "xattn", layer_params, h, positions,
                             cache=layer_cache, enc_kv=enc_kv)
        return h2, nc

    if not cfg.scan_layers:
        ncs = []
        for i in range(cfg.n_layers):
            x, nci = body(x, jax.tree.map(lambda a: a[i],
                                          (params["dec"], caches)))
            ncs.append(nci)
        nc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
    else:
        x, nc = jax.lax.scan(body, x, (params["dec"], caches))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0] @ params["unembed"].astype(cfg.adtype)).astype(jnp.float32)
    return logits, nc


def encdec_prefill(cfg: ModelConfig, params: Params, enc_out: jnp.ndarray,
                   caches: Any, tokens: jnp.ndarray, pos: jnp.ndarray,
                   n_valid: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
    """Chunked, batched decoder cache fill (see transformer.lm_prefill):
    tokens (B, C), pos (B,), n_valid (B,) -> (logits (B, V) at each
    row's last valid token, new caches)."""
    b, c = tokens.shape
    positions = pos[:, None] + jnp.arange(c, dtype=pos.dtype)[None, :]
    valid = jnp.arange(c)[None, :] < n_valid[:, None]
    x = embed_tokens(cfg, params, tokens)

    def body(h, pc):
        layer_params, layer_cache = pc
        enc_kv = attn.cross_kv(cfg, layer_params["xattn"], enc_out)
        h2, nc = block_apply(cfg, "xattn", layer_params, h, positions,
                             cache=layer_cache, enc_kv=enc_kv, valid=valid)
        return h2, nc

    if not cfg.scan_layers:
        ncs = []
        for i in range(cfg.n_layers):
            x, nci = body(x, jax.tree.map(lambda a: a[i],
                                          (params["dec"], caches)))
            ncs.append(nci)
        nc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
    else:
        x, nc = jax.lax.scan(body, x, (params["dec"], caches))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    last = jnp.clip(n_valid - 1, 0, c - 1)[:, None, None]
    xl = jnp.take_along_axis(x, last, axis=1)[:, 0]
    logits = (xl @ params["unembed"].astype(cfg.adtype)).astype(jnp.float32)
    return logits, nc
