"""Autotuning sweep: search decoupling parameters, persist winners.

``python -m benchmarks.run tune`` tunes

  * the simulator-backed DAE workloads (rif × channel-capacity slack,
    cycle-count objective) for the paper's pointer-chasing benchmarks;
  * the Pallas kernels (block shape / ring depth, wall-clock objective)
    at the shapes kernel_bench measures.

Winners land in the JSON cache (``repro.tune.cache_path()``; override
with ``$REPRO_TUNE_CACHE``).  A second invocation hits the cache:
``evals=0;cached=1`` in the output.  ``$REPRO_TUNE_FORCE=1`` re-searches.
"""

from __future__ import annotations

import os


def run(csv_print) -> None:
    from repro.tune import (cache_path, default_cache, tune_kernel,
                            tune_workload)

    force = bool(os.environ.get("REPRO_TUNE_FORCE"))

    # -- simulator backend: rif × cap_slack per workload --------------------
    for bench, cfg in (("hashtable", "rhls_dec"),
                       ("binsearch", "rhls_dec"),
                       ("spmv", "rhls_dec"),
                       ("mergesort_opt", "rhls_dec")):
        res = tune_workload(bench, cfg, scale="small", latency=100,
                            max_evals=32, force=force)
        cached = int(res.evals == 0)
        csv_print(
            f"tune/workload/{bench}/{cfg},0,"
            f"best_cycles={res.best_score:.0f};rif={res.best.get('rif')};"
            f"cap_slack={res.best.get('cap_slack')};"
            f"seed_cycles={res.seed_score:.0f};evals={res.evals};"
            f"cached={cached}")

    # -- wall-clock backend: kernel block shapes / ring depth ---------------
    # grouped_matmul rides with a contenders=2 leg: the same op tuned
    # solo and under 2-tenant makespan scoring, persisting under the
    # per-N wallclock:contenders=2 key (paper §5.4)
    for op, dims, contenders in (("dae_gather", (4096, 256, 512), 1),
                                 ("dae_merge", (2048, 2048), 1),
                                 ("batched_searchsorted", (4096, 256), 1),
                                 ("grouped_matmul", (256, 128, 128), 1),
                                 ("grouped_matmul", (256, 128, 128), 2)):
        res = tune_kernel(op, dims, max_evals=16, reps=2,
                          contenders=contenders, force=force)
        cached = int(res.evals == 0)
        best = ";".join(f"{k}={v}" for k, v in sorted(res.best.items()))
        leg = op if contenders == 1 else f"{op}/contenders={contenders}"
        csv_print(
            f"tune/kernel/{leg},{res.best_score * 1e6:.0f},"
            f"{best};seed_us={res.seed_score * 1e6:.0f};"
            f"evals={res.evals};cached={cached}")

    cache = default_cache()
    csv_print(f"tune/cache,0,path={cache_path()};entries={len(cache)};"
              f"hits={cache.hits};misses={cache.misses}")
