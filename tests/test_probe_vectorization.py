"""Cycle-level pin of the hash_probe SMEM->VMEM vectorization win.

The Pallas ``hash_probe`` kernel once kept found/val state in SMEM and
walked it with per-scalar ``fori_loop``s; moving that state to VMEM
vectors turned the per-level init and emit into single vector ops
(src/repro/kernels/dae_chase/kernel.py).  The wall-clock win
(3650 -> 2590 us on the bench box) is environment-dependent, so the
benchmark cell only *records* it — the regression pin lives here, on
the simulator, where the comparison is deterministic.

Both variants are modelled as DAE programs with *identical* memory
behaviour (same requests per level, same ring depth): the only
difference is the execute process's bookkeeping — a chunk-long scalar
loop per level for init and emit in the scalar-SMEM baseline, one
vector op each in the vectorized form.  The simulator must show the
vectorized probe strictly cheaper while doing exactly the same memory
work, on both scheduler engines, bit-exactly.
"""

from __future__ import annotations

import pytest

from repro.core.dae import DaeProgram, Delay, LoadChannel, Process, Req, \
    Resp, Store
from repro.core.simulator import FixedLatencyMemory, simulate
from repro.core.waveform import WaveformTracer

CHUNK, LEVELS, RIF, LATENCY = 16, 6, 8, 100
N = CHUNK * LEVELS


def _probe_program(vectorized: bool) -> DaeProgram:
    load = LoadChannel("probe_ld", capacity=RIF, port="entries")

    def bookkeeping():
        # scalar-SMEM baseline: one scalar op per key per pass;
        # vectorized: one vector op for the whole chunk
        for _ in range(1 if vectorized else CHUNK):
            yield Delay(1)

    def access():
        # lock-step chain walk: every level re-requests each chain's
        # cursor (the paper's fixed-length redundant loads), RIF of
        # them in flight
        for lv in range(LEVELS):
            for k in range(CHUNK):
                yield Req(load, lv * CHUNK + k)

    def execute():
        acc = [0] * CHUNK
        for lv in range(LEVELS):
            yield from bookkeeping()          # found/val init
            for k in range(CHUNK):
                v = yield Resp(load)
                acc[k] += v                   # per-key compare (scalar in
                yield Delay(1)                # both variants: chain cursor)
            yield from bookkeeping()          # found/val emit
        for k in range(CHUNK):
            yield Store("out", k, acc[k])

    name = "probe_vec" if vectorized else "probe_scalar"
    return DaeProgram(name, [Process("access", access),
                             Process("execute", execute)])


def _run(vectorized: bool, engine: str = "event"):
    mems = {"entries": FixedLatencyMemory(list(range(N)), latency=LATENCY),
            "out": FixedLatencyMemory([None] * CHUNK, latency=1)}
    tracer = WaveformTracer()
    res = simulate(_probe_program(vectorized), mems, tracer=tracer,
                   engine=engine)
    return res, tracer


def test_vectorized_probe_beats_scalar_smem_cycles():
    scalar, t_scalar = _run(vectorized=False)
    vec, t_vec = _run(vectorized=True)

    # same answer, same memory work: the win is pure bookkeeping
    assert vec.stored_array("out", CHUNK) == scalar.stored_array("out", CHUNK)
    assert t_vec.issues_until("entries", t_vec.end_cycle) == \
        t_scalar.issues_until("entries", t_scalar.end_cycle) == N

    # the pin: vectorized init/emit must stay strictly cheaper.  The
    # scalar baseline burns 2*(CHUNK-1) extra execute cycles per level;
    # memory latency hides some but must not hide all of it.
    assert vec.cycles < scalar.cycles, (
        f"vectorized probe ({vec.cycles} cycles) no longer beats the "
        f"scalar-SMEM baseline ({scalar.cycles} cycles)")


@pytest.mark.parametrize("vectorized", [False, True])
def test_probe_model_is_engine_exact(vectorized):
    """Both variants stay bit-exact across the event/polling engines, so
    the pin above cannot drift with the scheduler implementation."""
    ev, _ = _run(vectorized, engine="event")
    po, _ = _run(vectorized, engine="polling")
    assert ev.cycles == po.cycles
    assert ev.stored_array("out", CHUNK) == po.stored_array("out", CHUNK)
