"""Sharding-rule edge cases (parallel/sharding.py).

Uses AbstractMesh so an 8-way ``data`` axis can be described without
forcing host devices — the rules only read axis names/sizes, and
NamedSharding accepts an abstract mesh for spec inspection."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.parallel.sharding import (ShardingRules, _divisible,
                                     cache_shardings, page_table_sharding,
                                     param_shardings)

MESH8 = AbstractMesh((("data", 8),))


def _sds(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_divisible_requires_axis_and_divisibility():
    assert _divisible(16, MESH8, "data")
    assert not _divisible(12, MESH8, "data")    # 12 % 8 != 0
    assert not _divisible(16, MESH8, "model")   # axis absent


def test_cache_batch_sharded_when_divisible():
    tree = {"attn": {"k": _sds((2, 8, 4, 64, 16)),
                     "len": _sds((2, 8), jnp.int32)}}
    out = cache_shardings(tree, MESH8)
    assert out["attn"]["k"].spec == P(None, "data", None, None, None)
    # 2-D leaves (per-slot lengths) always replicate
    assert out["attn"]["len"].spec == P(None, None)


def test_cache_seq_shard_fallback_when_batch_does_not_divide():
    # batch 6 % 8 != 0 -> contiguous k/v fall back to sequence sharding
    tree = {"attn": {"k": _sds((2, 6, 4, 64, 16))}}
    out = cache_shardings(tree, MESH8)
    assert out["attn"]["k"].spec == P(None, None, None, "data", None)


def test_cache_full_replication_when_nothing_divides():
    # batch 6 and seq 60 both indivisible by 8 -> replicated
    tree = {"attn": {"k": _sds((2, 6, 4, 60, 16))}}
    out = cache_shardings(tree, MESH8)
    assert out["attn"]["k"].spec == P(None, None, None, None, None)


def test_seq_shard_respects_rules_flag():
    tree = {"attn": {"k": _sds((2, 6, 4, 64, 16))}}
    out = cache_shardings(tree, MESH8,
                          rules=ShardingRules(seq_shard_cache=False))
    assert out["attn"]["k"].spec == P(None, None, None, None, None)


def test_paged_pool_shards_page_dim():
    # pool leaves (count, n_pages, ...): page dim over data when divisible
    tree = [{"attn": {"kp": _sds((2, 64, 4, 8, 16)),
                      "vp": _sds((2, 64, 4, 8, 16)),
                      "len": _sds((2, 6), jnp.int32)}}]
    out = cache_shardings(tree, MESH8)
    assert out[0]["attn"]["kp"].spec == P(None, "data", None, None, None)
    assert out[0]["attn"]["vp"].spec == P(None, "data", None, None, None)
    assert out[0]["attn"]["len"].spec == P(None, None)


def test_paged_pool_replicates_never_seq_shards():
    # 33 pages % 8 != 0: replicate — sequence sharding would split
    # inside a page, and the batch rule must not fire on the page dim
    tree = [{"attn": {"kp": _sds((2, 33, 4, 8, 16)),
                      "ckvp": _sds((2, 33, 8, 32))}}]
    out = cache_shardings(tree, MESH8)
    assert out[0]["attn"]["kp"].spec == P(None, None, None, None, None)
    assert out[0]["attn"]["ckvp"].spec == P(None, None, None, None)


def test_paged_pool_mla_leaves_shard():
    tree = [{"attn": {"ckvp": _sds((2, 64, 8, 32)),
                      "krp": _sds((2, 64, 8, 16))}}]
    out = cache_shardings(tree, MESH8)
    assert out[0]["attn"]["ckvp"].spec == P(None, "data", None, None)
    assert out[0]["attn"]["krp"].spec == P(None, "data", None, None)


def test_page_table_sharding():
    assert page_table_sharding(MESH8, 16).spec == P("data", None)
    assert page_table_sharding(MESH8, 6).spec == P(None, None)   # 6 % 8
    assert page_table_sharding(MESH8, 0).spec == P(None, None)


def test_param_shardings_drop_indivisible_dims():
    # wq (D=96, H*hd=100): 100 % 8 != 0 on the model axis -> that dim
    # replicates; fsdp dim 96 % 8 == 0 -> data
    mesh = AbstractMesh((("data", 8), ("model", 8)))
    params = {"layers": {"wq": _sds((96, 100))}}
    out = param_shardings(params, mesh)
    assert out["layers"]["wq"].spec == P("data", None)
